"""Deep-chain checkout benchmark: fused chain pipeline vs stepwise applies.

The device-resident delta pipeline (:mod:`repro.store.delta` +
:mod:`repro.kernels.chain_apply`) exists to make deep delta chains cheap:
a K-step chain used to pay K ``to_blocks``/``sparse_apply``/``from_blocks``
round trips; fused, the whole chain is one padded device stack and one
Pallas dispatch per leaf-shape group.  This benchmark sweeps chain depth
over a linear history and measures cold ms/checkout through two otherwise
identical stores — ``fuse_chains=True`` vs ``False`` — verifying bit
identity at every depth (the fused path must be an optimization, never a
semantic change).

Acceptance: fused ≥ 3× faster at chain depth ≥ 16.

Results append to ``BENCH_serving_checkout.json`` (the serving benchmark's
history file — same serving tier, one timeline) tagged
``"benchmark": "delta_chain"``, and the suite registers as ``delta_chain``
in ``benchmarks.run`` with small depths for CI smoke.

Run standalone:
    PYTHONPATH=src python -m benchmarks.delta_chain [--depths 1,4,16,64]
        [--reps 5] [--shape 96x128]
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from typing import Dict, Iterable, List, Sequence

import numpy as np

from repro.store import VersionStore

from .common import Row
from .serving_checkout import BENCH_PATH, _NO_FLUSH, record

DEFAULT_DEPTHS = (1, 2, 4, 8, 16, 32, 64)
DEFAULT_REPS = 5
DEFAULT_SHAPE = (96, 128)


def build_linear_store(
    root: str, depth: int, *, shape=DEFAULT_SHAPE, seed: int = 0
) -> List[int]:
    """Linear history: one root + ``depth`` sparse-delta commits on one chain.

    Each commit perturbs a couple of rows (a block or two of the blocked
    layout), so every link stores as a sparse delta and a depth-d checkout
    genuinely walks d delta applies.
    """
    rng = np.random.RandomState(seed)
    store = VersionStore(
        root,
        cache_budget_bytes=0,
        delta_hops=depth + 1,
        access_flush_every=_NO_FLUSH,
    )
    payload = {
        "w": rng.randn(*shape).astype(np.float32),
        "b": rng.randn(shape[1]).astype(np.float32),
    }
    vids = [store.commit(payload, message="root")]
    for i in range(depth):
        payload = {k: v.copy() for k, v in payload.items()}
        row = rng.randint(0, shape[0] - 2)
        payload["w"][row : row + 2] += rng.randn(2, shape[1]).astype(np.float32)
        vids.append(store.commit(payload, parents=[vids[-1]], message=f"c{i}"))
    chain_links = sum(
        1 for v in vids if store.versions[v].stored_base is not None
    )
    assert chain_links == depth, f"expected a pure chain, got {chain_links}/{depth}"
    return vids


def run_benchmark(
    depths: Sequence[int] = DEFAULT_DEPTHS,
    *,
    reps: int = DEFAULT_REPS,
    shape=DEFAULT_SHAPE,
    seed: int = 0,
) -> Dict:
    max_depth = max(depths)
    sweep = []
    with tempfile.TemporaryDirectory(prefix="repro_chain_") as d:
        vids = build_linear_store(d, max_depth, shape=shape, seed=seed)
        fused = VersionStore(
            d, cache_budget_bytes=0, access_flush_every=_NO_FLUSH,
            fuse_chains=True,
        )
        stepwise = VersionStore(
            d, cache_budget_bytes=0, access_flush_every=_NO_FLUSH,
            fuse_chains=False,
        )
        for depth in depths:
            vid = vids[depth]
            t_f = _timed(fused, vid, reps)
            t_s = _timed(stepwise, vid, reps)
            f_tree = fused.checkout(vid)
            s_tree = stepwise.checkout(vid)
            identical = set(f_tree) == set(s_tree) and all(
                np.array_equal(f_tree[k], s_tree[k]) for k in f_tree
            )
            sweep.append(
                {
                    "depth": depth,
                    "fused_ms": round(t_f * 1e3, 4),
                    "stepwise_ms": round(t_s * 1e3, 4),
                    "speedup": round(t_s / max(t_f, 1e-9), 2),
                    "identical": bool(identical),
                }
            )
    deep = [p for p in sweep if p["depth"] >= 16]
    return {
        "benchmark": "delta_chain",
        "shape": list(shape),
        "reps": reps,
        "sweep": sweep,
        "all_identical": all(p["identical"] for p in sweep),
        "min_deep_speedup": min((p["speedup"] for p in deep), default=None),
    }


def _timed(store: VersionStore, vid: int, reps: int) -> float:
    store.checkout(vid)  # warmup: jit compiles off the clock (cache budget 0)
    t0 = time.monotonic()
    for _ in range(reps):
        store.checkout(vid)
    return (time.monotonic() - t0) / reps


def delta_chain(
    depths: Sequence[int] = (1, 4, 8), reps: int = 2
) -> Iterable[Row]:
    """``benchmarks.run`` suite adapter (small depths for CI smoke).

    The smoke asserts fused ≡ stepwise at every depth; the ≥3× deep-chain
    speedup is checked by the standalone CLI at depth ≥ 16.
    """
    result = run_benchmark(depths, reps=reps)
    record(result)
    assert result["all_identical"], "fused checkout diverged from stepwise"
    for p in result["sweep"]:
        yield Row(
            name=f"delta_chain/depth{p['depth']}",
            us_per_call=p["fused_ms"] * 1e3,
            derived=f"stepwise_ms={p['stepwise_ms']};speedup={p['speedup']}x",
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--depths", default=",".join(map(str, DEFAULT_DEPTHS)))
    ap.add_argument("--reps", type=int, default=DEFAULT_REPS)
    ap.add_argument("--shape", default="96x128")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    depths = tuple(int(x) for x in args.depths.split(","))
    shape = tuple(int(x) for x in args.shape.split("x"))
    result = run_benchmark(depths, reps=args.reps, shape=shape, seed=args.seed)
    record(result)
    print(json.dumps(result, indent=2))
    if not result["all_identical"]:
        raise SystemExit("FUSED/STEPWISE MISMATCH")
    deep = result["min_deep_speedup"]
    if deep is not None:
        ok = deep >= 3.0
        print(f"# min speedup at depth>=16: {deep}x ({'OK' if ok else 'BELOW 3x'})")


if __name__ == "__main__":
    main()
