"""System-layer benchmarks: delta kernels, store throughput, restore latency.

Kernel numbers on this container run under the Pallas *interpreter* (CPU) —
they validate plumbing and give relative shape behaviour; absolute GB/s on
TPU comes from the BlockSpec analysis in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import tempfile
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import OptimizeSpec, optimize
from repro.kernels import ops
from repro.store import VersionStore

from .common import Row, timed


def kernel_throughput() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.RandomState(0)
    for nb in (256, 1024, 4096):
        nbytes = nb * 4096
        a = jnp.asarray(
            rng.randint(-(2**31), 2**31, size=(nb, 8, 128), dtype=np.int64
                        ).astype(np.int32))
        b = a.at[jnp.arange(0, nb, 7)].add(3)

        out, us = timed(lambda: ops.xor_encode(a, b).block_until_ready(), repeats=3)
        rows.append(Row(f"kernel/xor/{nbytes>>20}MiB", us,
                        f"GBps_interpret={3*nbytes/us/1e3:.3f}"))
        out, us = timed(
            lambda: __import__("repro.kernels.block_diff", fromlist=["x"]).changed_block_mask(a, b).block_until_ready(),
            repeats=3)
        rows.append(Row(f"kernel/mask/{nbytes>>20}MiB", us,
                        f"GBps_interpret={2*nbytes/us/1e3:.3f}"))
        idx, blocks, n = ops.sparse_encode(a, b)
        out, us = timed(lambda: ops.sparse_apply(a, blocks, idx).block_until_ready(),
                        repeats=3)
        rows.append(Row(f"kernel/sparse_apply/{nbytes>>20}MiB", us,
                        f"changed={n};GBps_interpret={2*n*4096/us/1e3:.3f}"))
    return rows


def store_roundtrip() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.RandomState(1)
    payload = {"w": rng.randn(512, 512).astype(np.float32),
               "b": rng.randn(4096).astype(np.float32)}
    with tempfile.TemporaryDirectory() as d:
        store = VersionStore(d)
        _, us0 = timed(lambda: store.commit(payload, message="base"))
        vids = [1]
        def one_commit():
            payload["w"][rng.randint(0, 480):][:16] += 1.0
            vids.append(store.commit(payload, parents=[vids[-1]]))
        _, us_delta = timed(one_commit, repeats=5)
        # cold: fresh store handle with the FlatTree cache disabled
        cold = VersionStore(d, cache_budget_bytes=0)
        _, us_co = timed(lambda: cold.checkout(vids[-1]), repeats=3)
        # warm: the shared materialization cache serves the hot version
        store.checkout(vids[-1])  # populate
        _, us_warm = timed(lambda: store.checkout(vids[-1]), repeats=3)
        _, us_batch = timed(lambda: cold.checkout_many(vids), repeats=3)
        mb = sum(a.nbytes for a in payload.values()) / 1e6
        rows.append(Row("store/commit_full", us0, f"payload_mb={mb:.1f}"))
        rows.append(Row("store/commit_delta", us_delta,
                        f"stored_kb={store.log()[-1].stored_bytes/1e3:.1f}"))
        rows.append(Row("store/checkout_chain6_cold", us_co,
                        f"modelled_phi_ms={store.recreation_cost(vids[-1])*1e3:.2f}"))
        rows.append(Row("store/checkout_chain6_warm", us_warm,
                        f"speedup={us_co/max(us_warm,1e-9):.0f}x"))
        rows.append(Row("store/checkout_many_all6", us_batch,
                        "shared-prefix plan, uncached"))
    return rows


def restore_latency_vs_theta() -> List[Row]:
    """Problem 6 in vivo: tighter θ buys faster worst-case restore with more
    storage — measured on real checkpoint chains, wall-clock + modelled."""
    rows: List[Row] = []
    rng = np.random.RandomState(2)
    payload = {"w": rng.randn(384, 384).astype(np.float32)}
    with tempfile.TemporaryDirectory() as d:
        store = VersionStore(d)
        vid = store.commit(payload, message="v1")
        for i in range(11):
            payload = {"w": payload["w"].copy()}
            payload["w"][(i * 31) % 350:][:8] += 0.5
            vid = store.commit(payload, parents=[vid])
        g, _ = store.build_cost_graph()
        spt = optimize(g, OptimizeSpec.problem(2)).solution
        base = spt.max_recreation()
        for mult in (1.05, 2.0, 8.0):
            store.repack(OptimizeSpec.problem(6, theta=base * mult))
            worst_vid = max(store.versions, key=store.recreation_cost)
            t0 = time.monotonic()
            store.checkout(worst_vid)
            wall = (time.monotonic() - t0) * 1e6
            rows.append(Row(
                f"restore/theta{mult:g}x", wall,
                f"storage_mb={store.storage_bytes()/1e6:.2f};"
                f"modelled_worst_ms={store.recreation_cost(worst_vid)*1e3:.2f};"
                f"chain_len={max(_chain_len(store, v) for v in store.versions)}",
            ))
    return rows


def _chain_len(store: VersionStore, vid: int) -> int:
    n, v = 0, vid
    while v is not None:
        v = store.versions[v].stored_base
        n += 1
    return n
