"""Benchmarks reproducing the paper's experiment families (Figs 13-17, Tab 2).

Scaled to this CPU container (hundreds of versions rather than 100k) but
preserving the figures' comparisons and the claims being validated:

  fig13  storage ↔ Σ-recreation frontier, directed (LMG best balance)
  fig14  storage ↔ max-recreation, directed (MP best)
  fig15  same, undirected
  fig16  workload-aware LMG under Zipfian access beats oblivious
  fig17  solver running times vs n
  tab2   exact (B&B, stands in for Gurobi) vs MP storage at fixed θ
  git    §5.2-style: GitH/MCA storage vs store-everything
"""

from __future__ import annotations

import time
from typing import List

from repro.core import (
    OptimizeSpec,
    exact_min_storage,
    optimize,
    zipf_weights,
)
from repro.core.solvers.mp import InfeasibleError
from repro.core.version_graph import StorageSolution, VersionGraph

from .common import Row, random_cost_graph, timed, workload


def _solve(g: VersionGraph, n: int, **kw) -> StorageSolution:
    """One paper problem through the declarative spec API."""
    return optimize(g, OptimizeSpec.problem(n, **kw)).solution


def _heuristic(g: VersionGraph, solver: str, **kw) -> StorageSolution:
    return optimize(g, OptimizeSpec.heuristic(solver, **kw)).solution


def fig13_tradeoff_directed() -> List[Row]:
    rows: List[Row] = []
    for kind, n in (("dc", 220), ("lc", 220)):
        g = workload(kind, n).graph
        mca = _solve(g, 1)
        spt = _solve(g, 2)
        c0, r0, rmin = mca.storage_cost(), mca.sum_recreation(), spt.sum_recreation()
        for mult in (1.05, 1.1, 1.25, 1.5, 2.0, 3.0):
            sol, us = timed(lambda m=mult: _solve(g, 3, beta=c0 * m))
            rows.append(Row(
                f"fig13/{kind}/lmg@{mult:g}x", us,
                f"storage={sol.storage_cost():.3e};sum_rec={sol.sum_recreation():.3e};"
                f"rec_vs_spt={sol.sum_recreation()/rmin:.2f}",
            ))
        for alpha in (1.25, 1.5, 2.0, 3.0):
            sol, us = timed(lambda a=alpha: _heuristic(g, 'last', alpha=a))
            rows.append(Row(
                f"fig13/{kind}/last@a{alpha:g}", us,
                f"storage={sol.storage_cost():.3e};sum_rec={sol.sum_recreation():.3e}",
            ))
        for w in (10, 25, 50):
            sol, us = timed(lambda w=w: _heuristic(g, 'gith', window=w, max_depth=20))
            rows.append(Row(
                f"fig13/{kind}/gith@w{w}", us,
                f"storage={sol.storage_cost():.3e};sum_rec={sol.sum_recreation():.3e}",
            ))
        # headline claim: small storage slack slashes Σ-recreation vs MCA
        lmg11 = _solve(g, 3, beta=c0 * 1.1)
        rows.append(Row(
            f"fig13/{kind}/headline", 0.0,
            f"mca_sum_rec={r0:.3e};lmg1.1x_sum_rec={lmg11.sum_recreation():.3e};"
            f"reduction={r0 / lmg11.sum_recreation():.2f}x",
        ))
    return rows


def fig14_maxrec_directed() -> List[Row]:
    rows: List[Row] = []
    for kind in ("dc", "lc"):
        g = workload(kind, 220).graph
        mca = _solve(g, 1)
        spt = _solve(g, 2)
        budget_mults = (1.1, 1.5, 2.0, 3.0)
        for m in budget_mults:
            sol, us = timed(
                lambda m=m: _solve(g, 4, beta=mca.storage_cost() * m)
            )
            rows.append(Row(
                f"fig14/{kind}/mp@{m:g}x", us,
                f"storage={sol.storage_cost():.3e};max_rec={sol.max_recreation():.3e}",
            ))
            lmg = _solve(g, 3, beta=mca.storage_cost() * m)
            last = _heuristic(g, 'last', alpha=1.0 + m)
            rows.append(Row(
                f"fig14/{kind}/cmp@{m:g}x", 0.0,
                f"mp_max={sol.max_recreation():.3e};lmg_max={lmg.max_recreation():.3e};"
                f"last_max={last.max_recreation():.3e}",
            ))
    return rows


def fig15_undirected() -> List[Row]:
    rows: List[Row] = []
    for kind in ("dc", "bf"):
        g = workload(kind, 200, directed=False).graph
        mst = _solve(g, 1)
        for m in (1.1, 1.5, 2.5):
            lmg = _solve(g, 3, beta=mst.storage_cost() * m)
            rows.append(Row(
                f"fig15/{kind}/lmg@{m:g}x", 0.0,
                f"storage={lmg.storage_cost():.3e};sum_rec={lmg.sum_recreation():.3e}",
            ))
        la = _heuristic(g, 'last', alpha=2.0)
        rows.append(Row(
            f"fig15/{kind}/last@a2", 0.0,
            f"storage={la.storage_cost():.3e};sum_rec={la.sum_recreation():.3e}",
        ))
        spt = _solve(g, 2)
        try:
            mp = _solve(g, 6, theta=spt.max_recreation() * 1.5)
            rows.append(Row(
                f"fig15/{kind}/mp@1.5spt", 0.0,
                f"storage={mp.storage_cost():.3e};max_rec={mp.max_recreation():.3e}",
            ))
        except InfeasibleError:
            pass
    return rows


def fig16_workload_aware() -> List[Row]:
    rows: List[Row] = []
    for kind in ("dc", "lf"):
        g = workload(kind, 200).graph
        w = zipf_weights(g.n, exponent=2.0, seed=3)
        mca = _solve(g, 1)
        for m in (1.1, 1.5, 2.0):
            budget = mca.storage_cost() * m
            aware = _solve(g, 3, beta=budget, workload=w)
            blind = _solve(g, 3, beta=budget)
            rows.append(Row(
                f"fig16/{kind}/@{m:g}x", 0.0,
                f"aware_wrec={aware.sum_recreation(w):.3e};"
                f"oblivious_wrec={blind.sum_recreation(w):.3e};"
                f"gain={blind.sum_recreation(w)/max(aware.sum_recreation(w),1e-12):.2f}x",
            ))
    return rows


def fig17_running_times() -> List[Row]:
    """Solver runtimes vs n on precomputed-cost graphs (the paper times the
    algorithms, not delta construction — §5.3 'Running Times')."""
    rows: List[Row] = []
    for n in (100, 200, 400, 800, 1600):
        g = random_cost_graph(n, avg_deg=20, seed=1)
        mca, us_mca = timed(lambda: _solve(g, 1))
        spt, us_spt = timed(lambda: _solve(g, 2))
        _, us_lmg = timed(lambda: _solve(g, 3, beta=mca.storage_cost() * 1.5,
                                     base=mca, spt=spt))
        _, us_mp = timed(lambda: _solve(g, 6, theta=spt.max_recreation() * 2))
        _, us_last = timed(lambda: _heuristic(g, 'last', alpha=2.0, base=mca))
        _, us_gith = timed(lambda: _heuristic(g, 'gith', window=20, max_depth=20))
        rows.append(Row(
            f"fig17/n{n}", us_lmg,
            f"edges={g.n_edges};mca_us={us_mca:.0f};spt_us={us_spt:.0f};"
            f"lmg_us={us_lmg:.0f};mp_us={us_mp:.0f};last_us={us_last:.0f};"
            f"gith_us={us_gith:.0f}",
        ))
    return rows


def table2_exact_vs_mp() -> List[Row]:
    rows: List[Row] = []
    for n in (10, 15, 20):
        g = workload("dc", n, seed=4).graph
        spt = _solve(g, 2)
        base_theta = spt.max_recreation()
        for mult in (1.2, 1.5, 2.0, 3.0, 5.0):
            theta = base_theta * mult
            mp = _solve(g, 6, theta=theta)
            # seed the B&B with MP's solution — same role as warm-starting
            # Gurobi; the paper's Table 2 likewise reports best-found when
            # the optimizer hits its budget
            ex, us = timed(lambda t=theta: exact_min_storage(
                g, theta_max=t, time_budget_s=15, incumbent=mp))
            gap = mp.storage_cost() / max(ex.solution.storage_cost(), 1e-12)
            rows.append(Row(
                f"tab2/v{n}/theta{mult:g}x", us,
                f"exact={ex.solution.storage_cost():.3e};mp={mp.storage_cost():.3e};"
                f"gap={gap:.3f};optimal={ex.optimal};nodes={ex.nodes_explored}",
            ))
    return rows


def scale_trend() -> List[Row]:
    """The Fig-13 headline vs version count: MCA's Σ-recreation grows with
    chain depth while LMG@1.1x tracks the SPT floor; on this generator the
    reduction climbs from ~1.06x (n=100) to ~1.5-1.6x (n>=250) — the paper's
    orders-of-magnitude appear at 100k versions."""
    rows: List[Row] = []
    for n in (100, 250, 400):
        g = workload("lc", n, seed=9).graph
        mca = _solve(g, 1)
        spt = _solve(g, 2)
        lmg = _solve(g, 3, beta=mca.storage_cost() * 1.1, base=mca, spt=spt)
        rows.append(Row(
            f"scale/lc{n}", 0.0,
            f"mca_sum_rec={mca.sum_recreation():.3e};"
            f"lmg1.1_sum_rec={lmg.sum_recreation():.3e};"
            f"reduction={mca.sum_recreation()/lmg.sum_recreation():.2f}x;"
            f"spt_floor={spt.sum_recreation():.3e}",
        ))
    return rows


def git_comparison() -> List[Row]:
    """§5.2-style: store-everything vs GitH vs MCA storage on an LF shape."""
    g = workload("lf", 120).graph
    full = sum(g.materialization_cost(i).delta for i in g.versions())
    mca = _solve(g, 1)
    gith = _heuristic(g, 'gith', window=50, max_depth=50)
    return [Row(
        "git_cmp/lf120", 0.0,
        f"store_everything={full:.3e};gith={gith.storage_cost():.3e};"
        f"mca={mca.storage_cost():.3e};"
        f"gith_vs_mca={gith.storage_cost()/mca.storage_cost():.2f}x",
    )]
