"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig13,...]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated prefixes to run (default: all)")
    args = ap.parse_args()

    from . import delta_chain as dc
    from . import paper_figures as pf
    from . import serving_checkout as sc
    from . import serving_qps as sq
    from . import solver_scale as ss
    from . import system_benches as sb

    suites = [
        ("solver_scale", ss.solver_scale),
        ("serving_checkout", sc.serving_checkout),
        ("serving_qps", sq.serving_qps),
        ("delta_chain", dc.delta_chain),
        ("fig13", pf.fig13_tradeoff_directed),
        ("fig14", pf.fig14_maxrec_directed),
        ("fig15", pf.fig15_undirected),
        ("fig16", pf.fig16_workload_aware),
        ("fig17", pf.fig17_running_times),
        ("tab2", pf.table2_exact_vs_mp),
        ("git_cmp", pf.git_comparison),
        ("scale", pf.scale_trend),
        ("kernel", sb.kernel_throughput),
        ("store", sb.store_roundtrip),
        ("restore", sb.restore_latency_vs_theta),
    ]
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    t0 = time.monotonic()
    failures = 0
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            t1 = time.monotonic()
            for row in fn():
                print(row.csv())
            print(f"# suite {name} done in {time.monotonic()-t1:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# suite {name} FAILED: {type(e).__name__}: {e}",
                  file=sys.stderr)
    print(f"# total {time.monotonic()-t0:.1f}s, {failures} suite failures",
          file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
