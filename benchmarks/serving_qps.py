"""Sustained serving benchmark: open-loop zipfian traffic through the
``DatasetService`` tier, mixed checkout/commit, chain vs global invalidation.

Where ``serving_checkout`` measures the store's raw materialization paths,
this drives the *service* the way a client fleet would: requests arrive on
a Poisson process at a target rate whether or not earlier ones finished
(open loop — latency includes queue wait, so a saturated service shows up
as a p99 cliff rather than silently throttling the workload), version
popularity is zipfian, and a fraction of the traffic is commits appending
fresh versions while checkouts keep hitting the old hot set.

That interleaving is exactly the case the append-aware cache discipline
exists for, so the same recorded workload runs twice over identical copies
of the store:

* ``chain`` — per-entry decode-chain fingerprints; a commit appends to the
  storage graph and invalidates nothing it can't reach, so the hot set
  stays warm across writes;
* ``global`` — the legacy whole-graph epoch; every commit rotates the
  fingerprint and purges the cache wholesale.

Acceptance: the chain run's warm hit rate is **strictly higher** than the
global run's under any write traffic, and QPS/p99 move the same direction.
Results (per-mode QPS, p50/p99, hit rate, coalescing/batching counters)
append to ``BENCH_serving_qps.json``; the suite registers as
``serving_qps`` in ``benchmarks.run`` with a small n + short duration for
CI smoke.

Each measured run executes under an enabled :mod:`repro.obs` tracer: a
per-stage breakdown (queue wait / decode / device delta-apply span totals)
and a span↔metrics reconciliation ratio land in the per-mode results, and
both runs export into one Perfetto-loadable Chrome trace
(``BENCH_serving_qps_trace.json``, chain = pid 1, global = pid 2) so a
regression in the summary numbers can be opened as a timeline.

Run standalone:
    PYTHONPATH=src python -m benchmarks.serving_qps [--n 400]
        [--requests 800] [--qps 400] [--write-fraction 0.08] [--zipf 1.1]
        [--trace-out PATH]
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import shutil
import tempfile
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.obs import Tracer, chrome_trace, set_tracer, validate_chrome_trace
from repro.store.repository import Repository

from .common import Row
from .serving_checkout import _NO_FLUSH, build_store, zipf_requests

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_serving_qps.json"
TRACE_PATH = (
    Path(__file__).resolve().parent.parent / "BENCH_serving_qps_trace.json"
)
DEFAULT_N = 400
DEFAULT_REQUESTS = 800
DEFAULT_QPS = 400.0
DEFAULT_WRITE_FRACTION = 0.08
DEFAULT_ZIPF_S = 1.1


@dataclasses.dataclass
class _Event:
    """One scheduled arrival: offset from traffic start, op, payload."""

    at: float
    op: str  # "checkout" | "commit"
    vid: Optional[int] = None
    tree: Optional[dict] = None


def make_workload(
    vids: List[int],
    requests: int,
    *,
    qps: float,
    write_fraction: float,
    zipf_s: float,
    seed: int,
    shape=(48, 64),
) -> List[_Event]:
    """Poisson arrivals at ``qps``; zipfian reads, commits salted in."""
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / qps, size=requests)
    arrivals = np.cumsum(gaps)
    reads = zipf_requests(vids, requests, s=zipf_s, seed=seed + 1)
    events = []
    for i in range(requests):
        if rng.rand() < write_fraction:
            tree = {"w": rng.randn(*shape).astype(np.float32)}
            events.append(_Event(at=float(arrivals[i]), op="commit", tree=tree))
        else:
            events.append(
                _Event(at=float(arrivals[i]), op="checkout", vid=reads[i])
            )
    return events


async def run_traffic(
    repo: Repository,
    events: List[_Event],
    *,
    readers: int = 4,
    batch_window_s: float = 0.002,
    max_batch: int = 32,
    tracer: Optional[Tracer] = None,
) -> Dict:
    """Fire the recorded workload open-loop; return QPS + latency rollups."""
    async with repo.serve(
        readers=readers, batch_window_s=batch_window_s, max_batch=max_batch
    ) as svc:
        loop = asyncio.get_running_loop()
        t0 = loop.time()
        latencies: List[float] = []
        write_latencies: List[float] = []

        async def fire(ev: _Event) -> None:
            sched = t0 + ev.at
            if ev.op == "commit":
                await svc.commit(ev.tree, message="bench append")
                write_latencies.append(loop.time() - sched)
            else:
                await svc.checkout(ev.vid)
                latencies.append(loop.time() - sched)

        tasks = []
        for ev in events:
            delay = t0 + ev.at - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            tasks.append(loop.create_task(fire(ev)))
        await asyncio.gather(*tasks)
        makespan = loop.time() - t0
        snap = svc.stats()

    c = snap["counters"]
    hits = c.get("checkout.warm_hits", 0)
    misses = c.get("checkout.warm_misses", 0)

    def _pct(xs: List[float], q: float) -> float:
        from repro.service.metrics import percentile

        return round(percentile(xs, q) * 1e3, 4) if xs else 0.0

    stages: Dict[str, float] = {}
    recon: Dict[str, Optional[float]] = {}
    if tracer is not None:
        spansum = tracer.summary()

        def span_total(name: str) -> float:
            return spansum.get(name, {}).get("total_s", 0.0)

        def track_total(name: str) -> float:
            tr = snap["tracks"].get(name, {})
            return tr.get("mean_ms", 0.0) * tr.get("count", 0) / 1e3

        def ratio(a: float, b: float) -> Optional[float]:
            return round(a / b, 4) if b > 0 else None

        stages = {
            "queue_wait_ms": round(span_total("svc.queue_wait") * 1e3, 4),
            "decode_ms": round(span_total("svc.decode") * 1e3, 4),
            "delta_apply_ms": round(
                span_total("delta.apply_chains") * 1e3, 4
            ),
            "spans": len(tracer),
            "spans_dropped": tracer.dropped,
        }
        # spans and metrics are written from the same monotonic timestamps,
        # so these must sit at 1.0 within float noise — the benchmark's
        # acceptance gate pins them to ±5%
        recon = {
            "queue_wait": ratio(
                span_total("svc.queue_wait"), track_total("queue_wait")
            ),
            "decode": ratio(span_total("svc.decode"), track_total("decode")),
        }

    return {
        "requests": len(events),
        "reads": len(latencies),
        "commits": len(write_latencies),
        "makespan_s": round(makespan, 4),
        "qps": round(len(events) / makespan, 2),
        "read_p50_ms": _pct(latencies, 50),
        "read_p99_ms": _pct(latencies, 99),
        "commit_p50_ms": _pct(write_latencies, 50),
        "commit_p99_ms": _pct(write_latencies, 99),
        "hit_rate": round(hits / max(1, hits + misses), 4),
        "coalesced": c.get("checkout.coalesced", 0),
        "batches": c.get("checkout.batches", 0),
        "batched_refs": c.get("checkout.batched_refs", 0),
        "invalidations": snap["store"]["invalidations"],
        "purges": snap["store"]["purges"],
        "stages": stages,
        "span_reconciliation": recon,
    }


def run_benchmark(
    n: int = DEFAULT_N,
    *,
    requests: int = DEFAULT_REQUESTS,
    qps: float = DEFAULT_QPS,
    write_fraction: float = DEFAULT_WRITE_FRACTION,
    zipf_s: float = DEFAULT_ZIPF_S,
    readers: int = 4,
    seed: int = 0,
    trace_out: Optional[Path] = TRACE_PATH,
) -> Dict:
    """Build one store, replay one workload under both invalidation modes.

    Each mode's measured pass runs under its own enabled tracer; both export
    into one Chrome trace at ``trace_out`` (chain = pid 1, global = pid 2;
    ``None`` skips the artifact)."""
    with tempfile.TemporaryDirectory(prefix="repro_qps_") as d:
        base = Path(d) / "base"
        store = build_store(str(base), n, seed=seed)
        vids = sorted(store.versions)
        store.close()
        events = make_workload(
            vids,
            requests,
            qps=qps,
            write_fraction=write_fraction,
            zipf_s=zipf_s,
            seed=seed + 3,
        )

        modes: Dict[str, Dict] = {}
        tracers: Dict[str, Tracer] = {}
        for mode in ("chain", "global"):
            root = Path(d) / mode
            shutil.copytree(base, root)
            repo = Repository(
                str(root),
                cache_invalidation=mode,
                access_flush_every=_NO_FLUSH,
            )
            # build_store commits at the store layer; give the service a
            # branch tip for its write traffic to advance
            if "main" not in repo.branches():
                repo.branch("main", at=vids[-1])
            # one warmup pass over the read set so both modes start hot;
            # the measured pass then shows what write traffic costs each
            # (before the tracer installs — warmup decodes aren't the run)
            repo.store.checkout_many(
                sorted({e.vid for e in events if e.op == "checkout"})
            )
            tracer = Tracer(enabled=True, capacity=1 << 18)
            old = set_tracer(tracer)
            try:
                modes[mode] = asyncio.run(
                    run_traffic(
                        repo, events, readers=readers, tracer=tracer
                    )
                )
            finally:
                set_tracer(old)
            tracers[mode] = tracer
            repo.close()

    artifact = None
    if trace_out is not None:
        merged = chrome_trace(
            tracers["chain"], pid=1, process_name="serving_qps:chain"
        )
        chrome_trace(
            tracers["global"], trace_out, pid=2,
            process_name="serving_qps:global", base=merged,
        )
        artifact = str(trace_out)

    return {
        "n": n,
        "target_qps": qps,
        "write_fraction": write_fraction,
        "zipf_s": zipf_s,
        "readers": readers,
        "chain": modes["chain"],
        "global": modes["global"],
        "hit_rate_delta": round(
            modes["chain"]["hit_rate"] - modes["global"]["hit_rate"], 4
        ),
        "trace_artifact": artifact,
    }


def record(result: Dict, path: Path = BENCH_PATH) -> None:
    history = []
    if path.exists():
        history = json.loads(path.read_text())
    history.append(
        {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"), "result": result}
    )
    path.write_text(json.dumps(history, indent=2) + "\n")


def serving_qps(n: int = 120, requests: int = 300, qps: float = 300.0) -> Iterable[Row]:
    """``benchmarks.run`` suite adapter — small n / short duration so the
    orchestrator and CI smoke stay bounded; the CLI runs the full sweep."""
    result = run_benchmark(n, requests=requests, qps=qps)
    record(result)
    for mode in ("chain", "global"):
        r = result[mode]
        yield Row(
            name=f"serving_qps/{mode}/n{n}",
            us_per_call=1e6 / max(r["qps"], 1e-9),
            derived=(
                f"qps={r['qps']};p50={r['read_p50_ms']}ms;"
                f"p99={r['read_p99_ms']}ms;hit={r['hit_rate']};"
                f"coalesced={r['coalesced']};batches={r['batches']}"
            ),
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS)
    ap.add_argument("--qps", type=float, default=DEFAULT_QPS)
    ap.add_argument(
        "--write-fraction", type=float, default=DEFAULT_WRITE_FRACTION
    )
    ap.add_argument("--zipf", type=float, default=DEFAULT_ZIPF_S)
    ap.add_argument("--readers", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", type=Path, default=TRACE_PATH,
                    help="merged Chrome trace artifact path "
                         f"(default {TRACE_PATH.name})")
    args = ap.parse_args()
    result = run_benchmark(
        args.n,
        requests=args.requests,
        qps=args.qps,
        write_fraction=args.write_fraction,
        zipf_s=args.zipf,
        readers=args.readers,
        seed=args.seed,
        trace_out=args.trace_out,
    )
    record(result)
    print(json.dumps(result, indent=2))
    ok = result["chain"]["hit_rate"] > result["global"]["hit_rate"]
    ok_qps = result["chain"]["qps"] > 0 and result["chain"]["batches"] > 0
    print(
        f"# chain hit rate {result['chain']['hit_rate']} vs global "
        f"{result['global']['hit_rate']} "
        f"({'OK: append-aware strictly higher' if ok else 'REGRESSION'})"
    )
    ok_trace = True
    if result["trace_artifact"]:
        problems = validate_chrome_trace(result["trace_artifact"])
        ok_trace = not problems
        print(f"# trace artifact {result['trace_artifact']}: "
              f"{'Perfetto-loadable' if ok_trace else problems}")
    # span totals and ServiceMetrics tracks share one clock: ±5% or a stage
    # is being measured twice / not at all
    ok_recon = True
    for mode in ("chain", "global"):
        for stage, r in result[mode]["span_reconciliation"].items():
            if r is not None and not (0.95 <= r <= 1.05):
                ok_recon = False
                print(f"# RECONCILIATION FAILURE {mode}/{stage}: {r}")
    if not (ok and ok_qps and ok_trace and ok_recon):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
