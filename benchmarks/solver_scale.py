"""Solver wall-clock scaling on array-native synthetic instances.

Sweeps instance size n over 1k → 100k versions (the paper's §6 LF/DC scale),
generating each instance with :func:`repro.core.generate_flat` — edges land
directly in the flat ``EdgeArrays`` representation, no per-edge dict traffic
— and times every heuristic end to end through the declarative spec API
(``optimize(g, OptimizeSpec.problem(n, ...))`` — the surface production
callers use, so the numbers include spec validation):

* MCA (Problem 1), SPT (Problem 2), GitH;
* LMG at budget 1.05 × C_min (Problem 3);
* MP at θ = 1.5 × max SPT recreation (Problem 6).

Both solver backends are recorded: ``solvers`` holds the NumPy (Python-heap)
timings, ``solvers_jax`` the jitted backend (SPT Bellman-Ford relaxation, MP
scan, LMG device scoring).  The jax column measures the steady-state jitted
XLA path — ``pallas=False`` (on CPU the Pallas kernels run under the
interpreter, which benchmarks the interpreter, not the kernel) and a warmup
call per (solver, shape-bucket) so compile time is excluded.  MCA is
host-only (directed instances use Edmonds) and appears only under
``solvers``.

Results append to ``BENCH_solver_scale.json`` in the repo root: one entry
per run carrying the whole (n → seconds) trajectory per solver, so repeated
runs across PRs accumulate a history.  Also exposed as the ``solver_scale``
suite of ``benchmarks.run`` (CSV rows, capped at 20k versions to keep the
orchestrator fast).

Run standalone:
    PYTHONPATH=src python -m benchmarks.solver_scale [--ns 1000,5000,50000]
        [--backends numpy,jax]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core import OptimizeSpec, WorkloadSpec, generate_flat, optimize

from .common import Row

DEFAULT_NS = (1_000, 5_000, 20_000, 50_000)
DEFAULT_BACKENDS = ("numpy", "jax")
BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_solver_scale.json"


def _spec(n: int, seed: int = 0) -> WorkloadSpec:
    """DC-like shape with a bounded reveal ball (edges ≈ 20–30 per version)."""
    return WorkloadSpec(
        commits=n, branch_interval=3, branch_prob=0.7, branch_limit=4,
        branch_length=4, reveal_hops=3, seed=seed,
    )


def _timed(fn, *, warmup: bool = False) -> tuple:
    """(result, seconds); ``warmup=True`` runs once untimed first (jit)."""
    if warmup:
        fn()
    t0 = time.monotonic()
    out = fn()
    return out, time.monotonic() - t0


def sweep(
    ns: Iterable[int],
    seed: int = 0,
    backends: Sequence[str] = DEFAULT_BACKENDS,
) -> List[Dict]:
    results: List[Dict] = []
    for n in ns:
        t0 = time.monotonic()
        wl = generate_flat(_spec(n, seed=seed))
        g = wl.graph
        g.arrays()  # finalize the flat representation inside the gen timing
        gen_s = time.monotonic() - t0
        entry: Dict = {
            "n": n,
            "edges": g.n_edges,
            "generate_s": round(gen_s, 4),
            "solvers": {},
        }

        # the whole sweep speaks the declarative spec API (what production
        # callers hit); timings therefore include optimize()'s validation
        # and diagnostics pass, identically for both backends
        res, t = _timed(lambda: optimize(g, OptimizeSpec.problem(1)))
        mst = res.solution
        entry["solvers"]["mca"] = round(t, 4)

        res, t = _timed(lambda: optimize(g, OptimizeSpec.problem(2)))
        spt = res.solution
        entry["solvers"]["spt"] = round(t, 4)

        _, t = _timed(
            lambda: optimize(
                g, OptimizeSpec.heuristic("gith", window=10, max_depth=50)
            )
        )
        entry["solvers"]["gith"] = round(t, 4)

        budget = mst.storage_cost() * 1.05
        p3 = OptimizeSpec.problem(3, beta=budget, base=mst, spt=spt)
        lmg, t = _timed(lambda: optimize(g, p3))
        entry["solvers"]["lmg"] = round(t, 4)
        entry["lmg_budget_mult"] = 1.05
        entry["lmg_sum_rec_vs_mst"] = round(
            lmg.objective_value / max(mst.sum_recreation(), 1e-12), 6
        )

        theta = spt.max_recreation() * 1.5
        p6 = OptimizeSpec.problem(6, theta=theta)
        _, t = _timed(lambda: optimize(g, p6))
        entry["solvers"]["mp"] = round(t, 4)

        if "jax" in backends:
            jx: Dict[str, float] = {}
            res, t = _timed(
                lambda: optimize(g, OptimizeSpec.problem(2, backend="jax")),
                warmup=True,
            )
            spt_j = res.solution
            jx["spt"] = round(t, 4)
            _, t = _timed(
                lambda: optimize(
                    g,
                    OptimizeSpec.problem(
                        3, beta=budget, base=mst, spt=spt_j, backend="jax"
                    ),
                ),
                warmup=True,
            )
            jx["lmg"] = round(t, 4)
            _, t = _timed(
                lambda: optimize(
                    g, OptimizeSpec.problem(6, theta=theta, backend="jax")
                ),
                warmup=True,
            )
            jx["mp"] = round(t, 4)
            entry["solvers_jax"] = jx
            entry["spt_jax_speedup"] = round(
                entry["solvers"]["spt"] / max(jx["spt"], 1e-9), 3
            )

        results.append(entry)
    return results


def record(results: List[Dict], path: Path = BENCH_PATH) -> None:
    history = []
    if path.exists():
        history = json.loads(path.read_text())
    history.append(
        {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"), "results": results}
    )
    path.write_text(json.dumps(history, indent=2) + "\n")


def solver_scale(ns: Optional[Iterable[int]] = None) -> Iterable[Row]:
    """``benchmarks.run`` suite adapter: CSV rows, 20k cap for CI speed."""
    ns = tuple(ns) if ns is not None else tuple(
        n for n in DEFAULT_NS if n <= 20_000
    )
    results = sweep(ns)
    record(results)
    for entry in results:
        for col, suffix in (("solvers", ""), ("solvers_jax", "_jax")):
            for solver, seconds in entry.get(col, {}).items():
                yield Row(
                    name=f"solver_scale/{solver}{suffix}/n{entry['n']}",
                    us_per_call=seconds * 1e6,
                    derived=f"edges={entry['edges']}",
                )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--ns", default=",".join(str(n) for n in DEFAULT_NS),
        help="comma-separated instance sizes",
    )
    ap.add_argument(
        "--backends", default=",".join(DEFAULT_BACKENDS),
        help="comma-separated backends to time (numpy is always run; "
        "'jax' adds the jitted columns)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    try:
        ns = [int(x) for x in args.ns.split(",") if x.strip()]
    except ValueError:
        ap.error(f"--ns must be comma-separated integers, got {args.ns!r}")
    if not ns:
        ap.error("--ns is empty: nothing to sweep")
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    bad = set(backends) - {"numpy", "jax"}
    if bad:
        ap.error(f"unknown backends: {sorted(bad)}")
    results = sweep(ns, seed=args.seed, backends=backends)
    record(results)
    print(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
