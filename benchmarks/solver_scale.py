"""Solver wall-clock scaling on array-native synthetic instances.

Sweeps instance size n over 1k → 1M versions (past the paper's §6 LF/DC
scale, into mergeable-heap Edmonds territory),
generating each instance with :func:`repro.core.generate_flat` — edges land
directly in the flat ``EdgeArrays`` representation, no per-edge dict traffic
— and times every heuristic end to end through the declarative spec API
(``optimize(g, OptimizeSpec.problem(n, ...))`` — the surface production
callers use, so the numbers include spec validation):

* MCA (Problem 1), SPT (Problem 2), GitH;
* LMG at budget 1.05 × C_min (Problem 3);
* MP at θ = 1.5 × max SPT recreation (Problem 6).

Both solver backends are recorded: ``solvers`` holds the NumPy (Python-heap)
timings, ``solvers_jax`` the jitted backend (SPT Bellman-Ford relaxation, MP
scan, LMG device scoring).  The jax column measures the steady-state jitted
XLA path — ``pallas=False`` (on CPU the Pallas kernels run under the
interpreter, which benchmarks the interpreter, not the kernel) and a warmup
call per (solver, shape-bucket) so compile time is excluded.  MCA is
host-only (directed instances use Edmonds) and appears only under
``solvers``.

``BENCH_solver_scale.json`` in the repo root holds ``{"bounds", "history"}``:
``history`` accumulates one entry per run carrying the whole (n → seconds)
trajectory per solver (plus the process peak-RSS high-water mark after each
row), and ``bounds`` records a per-(solver, n) wall-clock reference in
seconds.  Every run doubles as a **timing-regression gate**: any timing above
``GATE_MULT`` (3×) its recorded bound fails the run — both standalone and as
the ``benchmarks.run`` suite (CSV rows, capped at 20k versions to keep the
orchestrator fast).  The 3× margin rides out scheduler noise on shared CI
boxes while still catching complexity-class regressions (the quadratic
regimes this sweep exists to guard against are 10–100× at the top sizes).
Refresh the references after an intentional perf change with
``--update-bounds``.

The default sweep ends at 500k and 1M versions (5.8M / 11.6M edges) — the
mergeable-heap Edmonds scale targets.  Pass ``--backends numpy`` for those
sizes: the jitted MP is a sequential O(n²) scan and the padded device layout
hits its cell cap near 1M versions.

Run standalone:
    PYTHONPATH=src python -m benchmarks.solver_scale [--ns 1000,5000,50000]
        [--backends numpy,jax] [--update-bounds]
"""

from __future__ import annotations

import argparse
import json
import resource
import sys
import time
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.core import OptimizeSpec, WorkloadSpec, generate_flat, optimize

from .common import Row

DEFAULT_NS = (1_000, 5_000, 20_000, 50_000, 500_000, 1_000_000)
DEFAULT_BACKENDS = ("numpy", "jax")
BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_solver_scale.json"

#: a timing may drift up to this factor above its recorded bound before the
#: gate fails the run
GATE_MULT = 3.0


def _spec(n: int, seed: int = 0) -> WorkloadSpec:
    """DC-like shape with a bounded reveal ball (edges ≈ 20–30 per version)."""
    return WorkloadSpec(
        commits=n, branch_interval=3, branch_prob=0.7, branch_limit=4,
        branch_length=4, reveal_hops=3, seed=seed,
    )


def _timed(fn, *, warmup: bool = False) -> tuple:
    """(result, seconds); ``warmup=True`` runs once untimed first (jit)."""
    if warmup:
        fn()
    t0 = time.monotonic()
    out = fn()
    return out, time.monotonic() - t0


def sweep(
    ns: Iterable[int],
    seed: int = 0,
    backends: Sequence[str] = DEFAULT_BACKENDS,
) -> List[Dict]:
    results: List[Dict] = []
    for n in ns:
        t0 = time.monotonic()
        wl = generate_flat(_spec(n, seed=seed))
        g = wl.graph
        g.arrays()  # finalize the flat representation inside the gen timing
        gen_s = time.monotonic() - t0
        entry: Dict = {
            "n": n,
            "edges": g.n_edges,
            "generate_s": round(gen_s, 4),
            "solvers": {},
        }

        # the whole sweep speaks the declarative spec API (what production
        # callers hit); timings therefore include optimize()'s validation
        # and diagnostics pass, identically for both backends
        res, t = _timed(lambda: optimize(g, OptimizeSpec.problem(1)))
        mst = res.solution
        entry["solvers"]["mca"] = round(t, 4)

        res, t = _timed(lambda: optimize(g, OptimizeSpec.problem(2)))
        spt = res.solution
        entry["solvers"]["spt"] = round(t, 4)

        _, t = _timed(
            lambda: optimize(
                g, OptimizeSpec.heuristic("gith", window=10, max_depth=50)
            )
        )
        entry["solvers"]["gith"] = round(t, 4)

        budget = mst.storage_cost() * 1.05
        p3 = OptimizeSpec.problem(3, beta=budget, base=mst, spt=spt)
        lmg, t = _timed(lambda: optimize(g, p3))
        entry["solvers"]["lmg"] = round(t, 4)
        entry["lmg_budget_mult"] = 1.05
        entry["lmg_sum_rec_vs_mst"] = round(
            lmg.objective_value / max(mst.sum_recreation(), 1e-12), 6
        )

        theta = spt.max_recreation() * 1.5
        p6 = OptimizeSpec.problem(6, theta=theta)
        _, t = _timed(lambda: optimize(g, p6))
        entry["solvers"]["mp"] = round(t, 4)

        if "jax" in backends:
            jx: Dict[str, float] = {}
            res, t = _timed(
                lambda: optimize(g, OptimizeSpec.problem(2, backend="jax")),
                warmup=True,
            )
            spt_j = res.solution
            jx["spt"] = round(t, 4)
            _, t = _timed(
                lambda: optimize(
                    g,
                    OptimizeSpec.problem(
                        3, beta=budget, base=mst, spt=spt_j, backend="jax"
                    ),
                ),
                warmup=True,
            )
            jx["lmg"] = round(t, 4)
            _, t = _timed(
                lambda: optimize(
                    g, OptimizeSpec.problem(6, theta=theta, backend="jax")
                ),
                warmup=True,
            )
            jx["mp"] = round(t, 4)
            entry["solvers_jax"] = jx
            entry["spt_jax_speedup"] = round(
                entry["solvers"]["spt"] / max(jx["spt"], 1e-9), 3
            )

        # ru_maxrss is the process lifetime high-water mark (KiB on Linux),
        # monotone across rows — the per-row value says "solving up to this n
        # fit in this much memory", which is the capacity-planning question
        entry["peak_rss_mib"] = round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1
        )
        results.append(entry)
    return results


def _timing_items(results: List[Dict]) -> Iterator[Tuple[str, float]]:
    """Flatten a sweep into ``("mca/n1000", seconds)`` bound-key pairs."""
    for entry in results:
        for col, suffix in (("solvers", ""), ("solvers_jax", "_jax")):
            for solver, seconds in entry.get(col, {}).items():
                yield f"{solver}{suffix}/n{entry['n']}", float(seconds)


def _load_bench(path: Path = BENCH_PATH) -> Dict:
    if not path.exists():
        return {"bounds": {}, "history": []}
    data = json.loads(path.read_text())
    if isinstance(data, list):
        # legacy layout: a bare run-history list from before the bounds gate
        return {"bounds": {}, "history": data}
    return data


def check_bounds(
    results: List[Dict], bounds: Dict[str, float], mult: float = GATE_MULT
) -> List[Tuple[str, float, float]]:
    """Timing-regression violations: ``(key, seconds, bound)`` for every
    swept timing above ``mult ×`` its recorded bound (unbounded keys pass)."""
    return [
        (key, seconds, bounds[key])
        for key, seconds in _timing_items(results)
        if key in bounds and seconds > mult * bounds[key]
    ]


def record(
    results: List[Dict], path: Path = BENCH_PATH, update_bounds: bool = False
) -> Dict[str, float]:
    """Append ``results`` to the history; returns the bounds table (refreshed
    from this run's timings when ``update_bounds``)."""
    data = _load_bench(path)
    data["history"].append(
        {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"), "results": results}
    )
    if update_bounds:
        for key, seconds in _timing_items(results):
            data["bounds"][key] = seconds
        data["bounds"] = dict(sorted(data["bounds"].items()))
    path.write_text(json.dumps(data, indent=2) + "\n")
    return data["bounds"]


def solver_scale(ns: Optional[Iterable[int]] = None) -> Iterable[Row]:
    """``benchmarks.run`` suite adapter: CSV rows, 20k cap for CI speed.

    Doubles as the timing-regression gate: raises after emitting its rows if
    any timing exceeds ``GATE_MULT ×`` its recorded bound.
    """
    ns = tuple(ns) if ns is not None else tuple(
        n for n in DEFAULT_NS if n <= 20_000
    )
    results = sweep(ns)
    bounds = record(results)
    for entry in results:
        for col, suffix in (("solvers", ""), ("solvers_jax", "_jax")):
            for solver, seconds in entry.get(col, {}).items():
                yield Row(
                    name=f"solver_scale/{solver}{suffix}/n{entry['n']}",
                    us_per_call=seconds * 1e6,
                    derived=f"edges={entry['edges']}",
                )
    violations = check_bounds(results, bounds)
    if violations:
        raise RuntimeError(
            "timing regression: " + "; ".join(
                f"{k} took {s:.3f}s > {GATE_MULT:g}x bound {b:.3f}s"
                for k, s, b in violations
            )
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--ns", default=",".join(str(n) for n in DEFAULT_NS),
        help="comma-separated instance sizes",
    )
    ap.add_argument(
        "--backends", default=",".join(DEFAULT_BACKENDS),
        help="comma-separated backends to time (numpy is always run; "
        "'jax' adds the jitted columns)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--update-bounds", action="store_true",
        help="refresh the per-(solver, n) timing bounds from this run "
        "instead of gating against them",
    )
    args = ap.parse_args()
    try:
        ns = [int(x) for x in args.ns.split(",") if x.strip()]
    except ValueError:
        ap.error(f"--ns must be comma-separated integers, got {args.ns!r}")
    if not ns:
        ap.error("--ns is empty: nothing to sweep")
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    bad = set(backends) - {"numpy", "jax"}
    if bad:
        ap.error(f"unknown backends: {sorted(bad)}")
    results = sweep(ns, seed=args.seed, backends=backends)
    bounds = record(results, update_bounds=args.update_bounds)
    print(json.dumps(results, indent=2))
    if not args.update_bounds:
        violations = check_bounds(results, bounds)
        for key, seconds, bound in violations:
            print(
                f"TIMING REGRESSION: {key} took {seconds:.3f}s "
                f"> {GATE_MULT:g}x bound {bound:.3f}s",
                file=sys.stderr,
            )
        if violations:
            sys.exit(1)


if __name__ == "__main__":
    main()
